"""Fabric scheduler benchmarks: overlap model, batched replay, autotuner,
cross-round operand residency, and cross-PROGRAM session residency.

Five numbers the fabric work is accountable for, written to
``BENCH_fabric.json`` (ROADMAP "benchmark hygiene" -- JSON artifact +
CI floor, mirroring ``engine_bench.py``):

* **modeled overlap** -- serial vs double-buffered
  (``ScheduleCost.overlapped_cycles``) latency for representative
  schedules; overlapped must be strictly below serial whenever a
  schedule has >= 2 rounds.
* **batched replay wall-clock** -- per-round ``execute_schedule`` vs
  batching every round into one ``engine.execute_blocks`` launch
  (rounds ride the compiled wide-block path as extra block-columns).
  This is the real CPU-time speedup; ``--min-batch-speedup X`` exits
  non-zero when it regresses below the floor (the CI gate).
* **residency** -- total ``TileLoad`` fetch count with the resident-tile
  map vs the reload-every-round baseline (the PR 4 data-movement win),
  on a weight-stationary schedule with >= 8 rounds and on a fused-QKV
  program; ``--min-residency-fetch-reduction X`` exits non-zero when
  the weight-stationary reduction drops below the floor (the CI gate).
* **session** -- a weight-stationary decode loop through ONE
  ``FabricSession``: per-step fetch trajectory, cold step-1 fetches vs
  the steady state (steps 2..N reuse the resident weight tiles), with
  outputs asserted bit-identical to the sessionless replay;
  ``--min-steady-state-fetch-reduction X`` exits non-zero when the
  cold/steady fetch ratio drops below the floor (the CI gate).
* **autotuner** -- ``search_schedule`` argmin vs the default geometry,
  priced by the costmodel (no execution), plus the chosen config and
  placement; ``tuned <= default`` is always asserted (the leg can't
  silently degrade) and ``--min-autotune-gain X`` gates a real win.

CLI: ``python benchmarks/fabric_bench.py [--quick] [--json PATH]
[--min-batch-speedup X] [--min-residency-fetch-reduction X]
[--min-steady-state-fetch-reduction X] [--min-autotune-gain X]``.
"""

import argparse
import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
import bench_util  # noqa: E402

from repro.pim import fabric  # noqa: E402
from repro.pim.fabric import FabricConfig  # noqa: E402

BENCH_JSON = "BENCH_fabric.json"


def _min_of(f, n=10):
    """Min-of-n wall clock (load-noise resistant); f() warmed up twice."""
    f(), f()
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_modeled(print_fn=print, quick=False):
    """Serial vs overlapped modeled cycles (pure costmodel, no sim)."""
    cases = [
        ("int4_16blk", 8, 96, 64, 4, FabricConfig(n_blocks=16)),
        ("int8_8blk", 4, 128, 40, 8, FabricConfig(n_blocks=8)),
    ]
    if not quick:
        cases.append(
            ("int8_64blk", 16, 256, 80, 8, FabricConfig(n_blocks=64)))
    results = {}
    for name, M, K, N, nbits, cfg in cases:
        sched = fabric.schedule_gemm(M, K, N, nbits, cfg=cfg, signed=True)
        cost = fabric.schedule_cost(sched)
        speedup = cost.overlap_speedup
        results[name] = {
            "shape": f"{M}x{K}x{N}", "nbits": nbits,
            "blocks": cfg.n_blocks, "rounds": len(sched.rounds),
            "serial_cycles": round(cost.serial_cycles_, 1),
            "overlapped_cycles": round(cost.overlapped_cycles_, 1),
            "overlap_speedup": round(speedup, 3),
        }
        print_fn(f"fabric/overlap_{name}/speedup,{speedup:.2f},"
                 f"serial={cost.serial_cycles_:.0f};"
                 f"overlapped={cost.overlapped_cycles_:.0f};"
                 f"rounds={len(sched.rounds)}")
        if len(sched.rounds) >= 2:
            assert cost.overlapped_cycles_ < cost.serial_cycles_, name
    return results


def bench_replay(print_fn=print, quick=False):
    """Wall-clock: per-round execute_schedule vs batched multi-round
    replay (one compiled wide-block launch for all rounds)."""
    rng = np.random.default_rng(0)
    # all-compute grid: every operand spills, many small rounds -- the
    # per-launch dispatch overhead the batched path amortizes
    cfg = FabricConfig(n_blocks=4, rows=128, cols=8, min_compute_blocks=4)
    M, K, N, nbits = (16, 40, 16, 4) if quick else (32, 80, 16, 4)
    sched = fabric.schedule_gemm(M, K, N, nbits, cfg=cfg)
    x = rng.integers(0, 1 << nbits, (M, K), dtype=np.uint64)
    w = rng.integers(0, 1 << nbits, (K, N), dtype=np.uint64)

    out_serial = fabric.execute_schedule(sched, x, w, batch_rounds=False)
    out_batch = fabric.execute_schedule(sched, x, w, batch_rounds=True)
    np.testing.assert_array_equal(out_serial, out_batch)   # bit-identical

    n = 5 if quick else 10
    t_serial = _min_of(
        lambda: fabric.execute_schedule(sched, x, w, batch_rounds=False), n)
    t_batch = _min_of(
        lambda: fabric.execute_schedule(sched, x, w, batch_rounds=True), n)
    speedup = t_serial / t_batch
    print_fn(f"fabric/batched_replay/speedup,{speedup:.2f},"
             f"rounds={len(sched.rounds)};serial_ms={t_serial*1e3:.2f};"
             f"batched_ms={t_batch*1e3:.2f}")
    return {
        "shape": f"{M}x{K}x{N}", "nbits": nbits,
        "rounds": len(sched.rounds), "n_compute": sched.n_compute,
        "per_round_ms": round(t_serial * 1e3, 3),
        "batched_ms": round(t_batch * 1e3, 3),
        "speedup": round(speedup, 2),
    }


def bench_residency(print_fn=print, quick=False):
    """TileLoad fetch counts: resident-tile map vs reload-every-round.

    The gated case is activation-stationary at M == n_compute (every
    activation slice returns to the block that already holds it) with
    the weight tiles broadcast once -- the schedule shape the residency
    refactor is accountable for.  A fused-QKV program is reported
    alongside (shared activation residency across three GEMMs).
    """
    cfg = FabricConfig(n_blocks=8, rows=128, cols=8, min_compute_blocks=8)
    M, K, N, nbits = 8, 10, 64, 4
    sched = fabric.schedule_gemm(M, K, N, nbits, cfg=cfg, signed=True)
    st = fabric.residency_stats(sched)
    assert len(sched.rounds) >= 8, "gate needs a many-round schedule"
    print_fn(f"fabric/residency/fetch_reduction,"
             f"{st['fetch_reduction']:.2f},"
             f"fetches={st['fetches']};reload={st['reload_fetches']};"
             f"hit_rate={st['hit_rate']:.2f};rounds={len(sched.rounds)}")

    # fused QKV: three GEMMs sharing activations in ONE grid allocation
    specs = tuple(fabric.GemmSpec(n_, M, K, N // 2) for n_ in "qkv")
    fused = fabric.schedule_program(specs, nbits, cfg=cfg, signed=True)
    stf = fabric.residency_stats(fused)
    print_fn(f"fabric/residency_qkv/fetch_reduction,"
             f"{stf['fetch_reduction']:.2f},"
             f"hit_rate={stf['hit_rate']:.2f};"
             f"rounds={len(fused.rounds)};gemms={len(fused.gemms)}")
    return {
        "shape": f"{M}x{K}x{N}", "nbits": nbits, "blocks": cfg.n_blocks,
        "rounds": len(sched.rounds),
        "fetches": st["fetches"],
        "reload_fetches": st["reload_fetches"],
        "fetch_reduction": round(st["fetch_reduction"], 3),
        "hit_rate": round(st["hit_rate"], 3),
        "qkv_fetch_reduction": round(stf["fetch_reduction"], 3),
        "qkv_hit_rate": round(stf["hit_rate"], 3),
    }


def bench_session(print_fn=print, quick=False):
    """Cross-program residency: a weight-stationary decode loop through
    ONE :class:`fabric.FabricSession`.

    One (1, K) activation per step against a FIXED weight: step 1
    fetches every weight tile (cold), steps 2..N reuse the session's
    resident tiles and fetch only the step's fresh activation row -- the
    per-step trajectory collapses, and the cold/steady fetch ratio is
    the gated number.  Outputs are asserted bit-identical to the
    sessionless replay of the same operands (residency is accounting,
    never arithmetic).
    """
    rng = np.random.default_rng(0)
    cfg = FabricConfig(n_blocks=8, rows=128, cols=8, min_compute_blocks=8)
    M, K, N, nbits = 1, 10, 64, 4
    steps = 4 if quick else 8
    lo, hi = -(1 << (nbits - 1)), (1 << (nbits - 1)) - 1
    xs = [rng.integers(lo, hi + 1, (M, K)).astype(np.int64)
          for _ in range(steps)]
    w = rng.integers(lo, hi + 1, (K, N)).astype(np.int64)

    sess = fabric.FabricSession(cfg)
    for x in xs:
        sess.begin_step()
        out = fabric.fabric_matmul(x, w, nbits=nbits, cfg=cfg,
                                   signed=True, session=sess).out
        ref = fabric.fabric_matmul(x, w, nbits=nbits, cfg=cfg,
                                   signed=True).out
        np.testing.assert_array_equal(out, ref)      # bit-identical
    traj = sess.trajectory()
    red = traj.steady_fetch_reduction
    print_fn(f"fabric/session/steady_state_fetch_reduction,{red:.2f},"
             f"cold={traj.cold_fetches};steady={traj.steady_fetches:.1f};"
             f"steps={steps};per_step={list(traj.fetches)}")
    rep = traj.report()
    rep.update({
        "shape": f"{M}x{K}x{N}", "nbits": nbits, "blocks": cfg.n_blocks,
        "decode_steps": steps,
        "steady_state_fetch_reduction": round(red, 3),
        "bit_identical_vs_sessionless": True,
    })
    return rep


def bench_autotune(print_fn=print, quick=False):
    """search_schedule argmin vs the default geometry (costmodel only).

    The shape is a single-row decode GEMM with a deep K: the default
    even storage/compute split starves compute, so the split/placement
    sweep has a real, deterministic win to find -- tuned strictly below
    default (both asserted and gated in ``main``).
    """
    M, K, N, nbits = 1, 256, 64, 8
    base = FabricConfig(n_blocks=16)
    default_cost = fabric.schedule_cost(
        fabric.schedule_gemm(M, K, N, nbits, cfg=base, signed=True))
    sr = fabric.search_schedule(M, K, N, nbits, base=base, signed=True)
    tuned = sr.cost
    gain = default_cost.overlapped_cycles_ / tuned.overlapped_cycles_
    cfg = sr.schedule.cfg
    print_fn(f"fabric/autotune/gain,{gain:.2f},"
             f"pick={cfg.rows}x{cfg.cols}mc{cfg.min_compute_blocks}"
             f"-{cfg.placement};candidates={len(sr.candidates)}")
    return {
        "shape": f"{M}x{K}x{N}", "nbits": nbits, "blocks": base.n_blocks,
        "candidates": len(sr.candidates),
        "default_overlapped_cycles": round(
            default_cost.overlapped_cycles_, 1),
        "tuned_overlapped_cycles": round(tuned.overlapped_cycles_, 1),
        "tuned_geometry": f"{cfg.rows}x{cfg.cols}",
        "tuned_min_compute": cfg.min_compute_blocks,
        "tuned_placement": cfg.placement,
        "gain": round(gain, 3),
    }


def run(print_fn=print, json_path=BENCH_JSON, quick=False):
    payload = {
        "quick": quick,
        "modeled": bench_modeled(print_fn, quick=quick),
        "replay": bench_replay(print_fn, quick=quick),
        "residency": bench_residency(print_fn, quick=quick),
        "session": bench_session(print_fn, quick=quick),
        "autotune": bench_autotune(print_fn, quick=quick),
    }
    if json_path:
        bench_util.atomic_write_json(json_path, payload, print_fn,
                                     tag="fabric")
    return payload


def check_batch_speedup(payload: dict, floor: float):
    """Return failure strings when the batched replay misses the floor."""
    s = payload["replay"]["speedup"]
    return [] if s >= floor else [f"batched replay: {s:.2f}x < {floor}x"]


def check_residency_reduction(payload: dict, floor: float):
    """Return failure strings when the residency fetch win regresses."""
    r = payload["residency"]["fetch_reduction"]
    return [] if r >= floor else \
        [f"residency fetch reduction: {r:.2f}x < {floor}x"]


def check_steady_state_reduction(payload: dict, floor: float):
    """Return failure strings when the session's cold/steady-state
    per-step fetch ratio regresses below the floor."""
    r = payload["session"]["steady_state_fetch_reduction"]
    return [] if r >= floor else \
        [f"session steady-state fetch reduction: {r:.2f}x < {floor}x"]


def check_autotune(payload: dict, min_gain=None):
    """Tuned must never degrade; optionally require a real win."""
    a = payload["autotune"]
    tuned, default = (a["tuned_overlapped_cycles"],
                      a["default_overlapped_cycles"])
    bad = []
    if tuned > default:
        bad.append(f"autotune degraded: tuned {tuned} > default {default}")
    if min_gain is not None and a["gain"] < min_gain:
        bad.append(f"autotune gain: {a['gain']:.3f}x < {min_gain}x")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller schedules + fewer replays (CI tier-1)")
    ap.add_argument("--json", default=BENCH_JSON,
                    help=f"output path (default {BENCH_JSON})")
    ap.add_argument("--min-batch-speedup", type=float, default=None,
                    metavar="X",
                    help="fail (exit 1) if batched-vs-per-round replay "
                    "speedup drops below X")
    ap.add_argument("--min-residency-fetch-reduction", type=float,
                    default=None, metavar="X",
                    help="fail (exit 1) if the residency fetch-count "
                    "reduction drops below X")
    ap.add_argument("--min-steady-state-fetch-reduction", type=float,
                    default=None, metavar="X",
                    help="fail (exit 1) if the session's cold vs "
                    "steady-state per-step fetch ratio drops below X")
    ap.add_argument("--min-autotune-gain", type=float, default=None,
                    metavar="X",
                    help="fail (exit 1) if the autotuner's gain over "
                    "the default geometry drops below X")
    args = ap.parse_args(argv)
    # gates run BEFORE the artifact exists (see bench_util)
    payload = run(json_path=None, quick=args.quick)
    bad = []
    if args.min_batch_speedup is not None:
        bad += check_batch_speedup(payload, args.min_batch_speedup)
    if args.min_residency_fetch_reduction is not None:
        bad += check_residency_reduction(
            payload, args.min_residency_fetch_reduction)
    if args.min_steady_state_fetch_reduction is not None:
        bad += check_steady_state_reduction(
            payload, args.min_steady_state_fetch_reduction)
    bad += check_autotune(payload, args.min_autotune_gain)
    if bench_util.gate_and_write(payload, bad, args.json, "fabric"):
        return 1
    if args.min_batch_speedup is not None:
        print(f"batched replay speedup >= {args.min_batch_speedup}x: OK")
    if args.min_residency_fetch_reduction is not None:
        print(f"residency fetch reduction >= "
              f"{args.min_residency_fetch_reduction}x: OK")
    if args.min_steady_state_fetch_reduction is not None:
        print(f"session steady-state fetch reduction >= "
              f"{args.min_steady_state_fetch_reduction}x: OK")
    if args.min_autotune_gain is not None:
        print(f"autotune gain >= {args.min_autotune_gain}x: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
