"""Serving-engine throughput (smoke-scale model on CPU; the derived
column carries the architectural quantity: decode step tokens/s scale).

Writes ``BENCH_serve.json`` (ROADMAP "benchmark hygiene" -- JSON
artifact + CI floor, mirroring the engine/fabric benches): tokens
served, per-token latency, and the continuous-batching accounting.
Wall-clock on shared CI is noisy, so the hard gate is an *integrity*
floor -- ``--min-tokens N`` fails when the engine stops producing the
expected token count (a scheduling/slot-refill regression), while the
latency number rides along as a tracked artifact.

CLI: ``python benchmarks/serve_bench.py [--quick] [--json PATH]
[--min-tokens N]``.
"""

import argparse
import json
import pathlib
import sys
import time

import numpy as np

import jax

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
import bench_util  # noqa: E402

from repro import configs  # noqa: E402
from repro.models.model import LM  # noqa: E402
from repro.serve.engine import Request, ServeEngine  # noqa: E402

BENCH_JSON = "BENCH_serve.json"


def run(print_fn=print, json_path=BENCH_JSON, quick=False):
    cfg = configs.get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots = 2 if quick else 4
    n_req, max_new = (4, 4) if quick else (8, 8)
    eng = ServeEngine(model, params, batch_slots=slots, capacity=64)
    rng = np.random.default_rng(0)
    for rid in range(n_req):
        eng.add(Request(rid=rid,
                        prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                        max_new=max_new))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    us_per_token = dt * 1e6 / max(toks, 1)
    print_fn(f"serve/continuous_batching,{us_per_token:.0f},"
             f"us_per_token;requests={len(done)};slots={slots};"
             f"tokens={toks}")
    payload = {
        "quick": quick,
        "model": "qwen2-0.5b-smoke",
        "slots": slots,
        "requests": len(done),
        "tokens": toks,
        "expected_tokens": n_req * max_new,
        "us_per_token": round(us_per_token),
        "wall_s": round(dt, 3),
    }
    if json_path:
        bench_util.atomic_write_json(json_path, payload, print_fn,
                                     tag="serve")
    return payload


def check_tokens(payload: dict, floor: int):
    """Failure strings when the engine under-produces tokens."""
    t = payload["tokens"]
    return [] if t >= floor else [f"tokens served: {t} < {floor}"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller batch + fewer requests (CI tier-1)")
    ap.add_argument("--json", default=BENCH_JSON,
                    help=f"output path (default {BENCH_JSON})")
    ap.add_argument("--min-tokens", type=int, default=None, metavar="N",
                    help="fail (exit 1) if fewer than N tokens are served "
                    "(continuous-batching integrity gate)")
    args = ap.parse_args(argv)
    # gates run BEFORE the artifact exists (see bench_util)
    payload = run(json_path=None, quick=args.quick)
    bad = []
    if args.min_tokens is not None:
        bad = check_tokens(payload, args.min_tokens)
    if bench_util.gate_and_write(payload, bad, args.json, "serve"):
        return 1
    if args.min_tokens is not None:
        print(f"tokens served >= {args.min_tokens}: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
