"""Serving-engine throughput (smoke-scale model on CPU; the derived
column carries the architectural quantity: decode step tokens/s scale).

Writes ``BENCH_serve.json`` (ROADMAP "benchmark hygiene" -- JSON
artifact + CI floor, mirroring the engine/fabric benches): tokens
served, per-token latency split by phase (prefill vs decode, and the
first -- cold -- decode step vs the warm steady state), and the
continuous-batching accounting.  Wall-clock on shared CI is noisy, so
the hard gates are *integrity* floors -- ``--min-tokens N`` fails when
the engine stops producing the expected token count (a
scheduling/slot-refill regression), and the **fabric leg** fails when
its tokens diverge from the ref leg's.

The fabric leg reruns the same request stream with the decode loop on
the simulated Compute RAM grid, two ways:

* a :class:`repro.pim.fabric.FabricLinearProbe` holding ONE
  :class:`FabricSession` across every decode step (the engine's live
  per-step activations through the fused QKV program; weights go
  resident at step 1, steps 2..N schedule warm) -- tokens must be
  bit-identical to the ref run;
* a multi-step decode loop through ``PimConfig(mode="fabric",
  fabric_session=...)`` / ``fused_linear_apply`` on the same layer-0
  projection weights, asserted bit-identical per step to the
  sessionless fabric path (residency is accounting, never arithmetic).

The **load sweep** drives hundreds of seeded Poisson arrivals through
the paged continuous-batching engine (chunked prefill, deadline-aware
admission, a couple of deliberately oversize prompts) and rolls the
per-request timestamps into serving SLOs: p50/p99 decode ms-per-token
and aggregate tokens/sec.  Its hard gates are integrity-first:

* every completed request's token chain must be **bit-identical** to a
  sequential single-slot reference run (batching, chunking, admission
  order, and preemption may never change tokens);
* the oversize prompts must be **rejected with accounting** on both
  legs (the old engine crashed);
* a **pressure** sub-leg with a deliberately undersized page pool must
  preempt at least once and still match the reference chains
  (recompute-style preemption is lossless under greedy decoding);
* ``--max-p99-ms-per-token`` / ``--min-tokens-per-s`` bound the SLO
  numbers (loose on shared CI -- wall-clock there is noisy; the chain
  identity above is the real regression tripwire).

On gate failure the sweep payload is preserved to
``BENCH_serve_repro.json`` (CI uploads it) and no artifact is written.

CLI: ``python benchmarks/serve_bench.py [--quick] [--json PATH]
[--min-tokens N] [--requests N] [--seed S]
[--max-p99-ms-per-token MS] [--min-tokens-per-s TPS]``.
"""

import argparse
import pathlib
import sys
import time

import numpy as np

import jax

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
import bench_util  # noqa: E402

from repro import configs  # noqa: E402
from repro.models.model import LM  # noqa: E402
from repro.serve.engine import Request, ServeEngine  # noqa: E402

BENCH_JSON = "BENCH_serve.json"


def _engine_run(model, cfg, params, slots, n_req, max_new, probe=None):
    """One full continuous-batching run; same seeded request stream."""
    eng = ServeEngine(model, params, batch_slots=slots, capacity=64,
                      fabric_probe=probe)
    rng = np.random.default_rng(0)
    for rid in range(n_req):
        eng.add(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
            max_new=max_new))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    return eng, sorted(done, key=lambda r: r.rid), dt


def _phase_split(stats: dict) -> dict:
    """prefill vs decode, cold first decode step vs warm steady state."""
    warm_steps = max(stats["decode_warm_steps"], 1)
    return {
        "prefill_us_per_token": round(
            stats["prefill_s"] * 1e6 / max(stats["prefill_tokens"], 1)),
        "decode_us_per_token": round(
            stats["decode_s"] * 1e6 / max(stats["decode_tokens"], 1)),
        "decode_cold_us_per_step": round(stats["decode_cold_s"] * 1e6),
        "decode_warm_us_per_step": round(
            stats["decode_warm_s"] * 1e6 / warm_steps),
        "decode_warm_steps": stats["decode_warm_steps"],
    }


def _bench_pim_decode(params, quick=False):
    """Multi-step decode loop through ``PimConfig(mode="fabric")``.

    The smoke model's layer-0 / head-0 Q/K/V projection slices, packed
    offline (``pack_linear``), applied to a fresh activation per decode
    step -- once through a shared :class:`FabricSession` (the
    weight-stationary loop) and once sessionless; outputs must match
    bit-for-bit, and the session trajectory shows the fetch collapse.
    """
    from repro.pim import fabric as fabric_mod
    from repro.pim.linear import PimConfig, fused_linear_apply, pack_linear

    attn = params["unit"]["b0"]["attn"]
    w3 = [np.asarray(attn["wq"][0][:, 0, :], np.float32),
          np.asarray(attn["wk"][0][:, 0, :], np.float32),
          np.asarray(attn["wv"][0][:, 0, :], np.float32)]
    packed = [pack_linear({"w": w}, PimConfig(weight_bits=8)) for w in w3]

    fcfg = fabric_mod.FabricConfig(n_blocks=8)
    sess = fabric_mod.FabricSession(fcfg)
    cfg_s = PimConfig(mode="fabric", weight_bits=8, act_bits=8,
                      fabric=fcfg, fabric_session=sess)
    cfg_0 = PimConfig(mode="fabric", weight_bits=8, act_bits=8, fabric=fcfg)
    steps = 3 if quick else 6
    rng = np.random.default_rng(1)
    identical = True
    for _ in range(steps):
        x = rng.normal(size=(1, w3[0].shape[0])).astype(np.float32)
        sess.begin_step()
        ys = fused_linear_apply(packed, x, cfg_s)
        y0 = fused_linear_apply(packed, x, cfg_0)
        identical &= all(
            np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
            for a, b in zip(ys, y0))
    traj = sess.trajectory()
    rep = traj.report()
    rep["bit_identical_vs_sessionless"] = bool(identical)
    return rep


def _bench_load_sweep(model, cfg, params, quick, n_requests, seed,
                      print_fn=print):
    """Seeded Poisson load sweep vs a sequential reference.

    One generated load set drives three engines: a single-slot
    sequential reference (defines the truth token chain per request
    id), the gated continuous-batching sweep (chunked prefill +
    deadline-aware admission), and a page-pressure sub-leg whose
    undersized pool forces preemption.  Chains must match the
    reference everywhere; latency rollups come from the sweep leg.
    """
    from repro.serve import loadgen

    capacity = 64
    lcfg = loadgen.LoadConfig(
        n_requests=n_requests, seed=seed, arrival="poisson", rate=2.0,
        prompt_len=(4, 16), max_new=(2, 8), vocab=cfg.vocab,
        deadline_frac=0.25,
        # a couple of oversize prompts per sweep: the admission-
        # rejection path runs under real traffic on every leg
        oversize_frac=2.5 / n_requests, oversize_len=capacity + 1)
    arrivals = loadgen.generate(lcfg)

    # --- sequential reference: 1 slot, whole prefill, no arrival noise
    ref_eng = ServeEngine(model, params, batch_slots=1, capacity=capacity)
    ref = loadgen.drive(
        ref_eng, [(0.0, r) for _, r in loadgen.clone_requests(arrivals)])
    truth = {r.rid: list(r.out) for r in ref["done"]}
    ref_rejected = {r.rid for r in ref_eng.rejected}

    # --- the gated sweep: paged continuous batching under open load
    slots = 4 if quick else 8
    eng = ServeEngine(model, params, batch_slots=slots, capacity=capacity,
                      prefill_chunk=16, admission="deadline")
    rec = loadgen.drive(eng, loadgen.clone_requests(arrivals))
    rep = loadgen.latency_report(rec["done"], rec["wall_s"], eng)
    chains_ok = ({r.rid: list(r.out) for r in rec["done"]} == truth)
    rejects_ok = ({r.rid for r in eng.rejected} == ref_rejected
                  and (len(ref_rejected) > 0) == (lcfg.oversize_frac > 0))

    # --- pressure sub-leg: undersized pool -> preemption, same chains
    n_press = min(40, n_requests)
    press_arr = [(at, r) for at, r in loadgen.clone_requests(arrivals)
                 if r.rid < n_press]
    peng = ServeEngine(model, params, batch_slots=4, capacity=capacity,
                       page_size=8, num_pages=6, prefill_chunk=8)
    prec = loadgen.drive(peng, press_arr)
    press_ok = all(truth.get(r.rid) == list(r.out) for r in prec["done"]) \
        and {r.rid for r in prec["done"]} == \
            {rid for rid in truth if rid < n_press}

    rep.update({
        "arrival": lcfg.arrival, "rate": lcfg.rate, "seed": seed,
        "requests": n_requests, "slots": slots,
        "prefill_chunk": 16, "admission": "deadline",
        "chains_bit_identical": bool(chains_ok),
        "rejections_match_reference": bool(rejects_ok),
        "kv": eng.kv_report(),
        "pressure": {
            "requests": n_press,
            "num_pages": 6, "page_size": 8,
            "preemptions": peng.stats["preemptions"],
            "resumes": peng.stats["resumes"],
            "chains_bit_identical": bool(press_ok),
            "kv_high_water_pages":
                peng.kv.stats["high_water_pages"],
        },
    })
    print_fn(f"serve/load_sweep,{rep['p99_ms']},p99_ms_per_token;"
             f"requests={n_requests};done={rep['requests_done']};"
             f"tokens_per_s={rep['tokens_per_s']};"
             f"p50={rep['p50_ms']};rejected={rep['rejected']};"
             f"chains_identical={chains_ok}")
    print_fn(f"serve/load_pressure,{peng.stats['preemptions']},"
             f"preemptions;resumes={peng.stats['resumes']};"
             f"chains_identical={press_ok}")
    return rep


def run(print_fn=print, json_path=BENCH_JSON, quick=False,
        n_requests=None, seed=0):
    from repro.pim import fabric as fabric_mod

    cfg = configs.get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots = 2 if quick else 4
    n_req, max_new = (4, 4) if quick else (8, 8)

    # --- ref leg: host decode, no fabric --------------------------------
    eng, done, dt = _engine_run(model, cfg, params, slots, n_req, max_new)
    toks = sum(len(r.out) for r in done)
    us_per_token = dt * 1e6 / max(toks, 1)
    split = _phase_split(eng.stats)
    print_fn(f"serve/continuous_batching,{us_per_token:.0f},"
             f"us_per_token;requests={len(done)};slots={slots};"
             f"tokens={toks}")
    print_fn(f"serve/phase_split,{split['decode_warm_us_per_step']},"
             f"decode_warm_us_per_step;"
             f"prefill={split['prefill_us_per_token']};"
             f"decode={split['decode_us_per_token']};"
             f"cold_step={split['decode_cold_us_per_step']}")

    # --- fabric leg: same stream, decode loop on the block grid ---------
    attn = params["unit"]["b0"]["attn"]
    w3 = [np.asarray(attn["wq"][0][:, 0, :], np.float32),
          np.asarray(attn["wk"][0][:, 0, :], np.float32),
          np.asarray(attn["wv"][0][:, 0, :], np.float32)]
    probe = fabric_mod.FabricLinearProbe(
        w3, cfg=fabric_mod.FabricConfig(n_blocks=8), bits=8,
        max_steps=n_req * max_new, session=True)
    feng, fdone, fdt = _engine_run(model, cfg, params, slots, n_req,
                                   max_new, probe=probe)
    ftoks = sum(len(r.out) for r in fdone)
    identical = [r.out for r in done] == [r.out for r in fdone]
    fsplit = _phase_split(feng.stats)
    straj = probe.session.trajectory()
    print_fn(f"serve/fabric_decode,{fdt * 1e6 / max(ftoks, 1):.0f},"
             f"us_per_token;steps={len(probe.costs)};"
             f"tokens_bit_identical={identical};"
             f"steady_fetch_reduction="
             f"{straj.steady_fetch_reduction:.2f}")

    pim = _bench_pim_decode(params, quick=quick)
    print_fn(f"serve/pim_fabric_decode,"
             f"{pim['steady_fetch_reduction']:.2f},"
             f"steady_fetch_reduction;steps={pim['steps']};"
             f"bit_identical={pim['bit_identical_vs_sessionless']}")

    # --- load sweep: seeded open-loop traffic through the paged engine
    if n_requests is None:
        n_requests = 120 if quick else 500
    load = _bench_load_sweep(model, cfg, params, quick, n_requests, seed,
                             print_fn=print_fn)

    payload = {
        "quick": quick,
        "model": "qwen2-0.5b-smoke",
        "slots": slots,
        "requests": len(done),
        "tokens": toks,
        "expected_tokens": n_req * max_new,
        "us_per_token": round(us_per_token),
        "wall_s": round(dt, 3),
        **split,
        "fabric": {
            "tokens": ftoks,
            "tokens_bit_identical": identical,
            "us_per_token": round(fdt * 1e6 / max(ftoks, 1)),
            "decode_steps_on_fabric": len(probe.costs),
            **{k: fsplit[k] for k in ("decode_cold_us_per_step",
                                      "decode_warm_us_per_step")},
            "session": straj.report(),
            "probe": probe.report(),
        },
        "pim_decode": pim,
        "load": load,
    }
    if json_path:
        bench_util.atomic_write_json(json_path, payload, print_fn,
                                     tag="serve")
    return payload


def check_tokens(payload: dict, floor: int):
    """Failure strings when the engine under-produces tokens."""
    t = payload["tokens"]
    return [] if t >= floor else [f"tokens served: {t} < {floor}"]


def check_fabric_identity(payload: dict):
    """The fabric leg must serve the exact ref-path token stream, and
    the session-vs-sessionless PIM decode must match bit-for-bit."""
    bad = []
    if not payload["fabric"]["tokens_bit_identical"]:
        bad.append("fabric leg tokens diverge from the ref path")
    if not payload["pim_decode"]["bit_identical_vs_sessionless"]:
        bad.append("PimConfig(fabric) session outputs diverge from "
                   "the sessionless path")
    return bad


def check_load(payload: dict, max_p99_ms=None, min_tokens_per_s=None):
    """The load sweep's integrity gates (always on) plus the optional
    latency/throughput SLO bounds."""
    load = payload["load"]
    bad = []
    if not load["chains_bit_identical"]:
        bad.append("load sweep token chains diverge from the sequential "
                   "reference")
    if not load["rejections_match_reference"]:
        bad.append("oversize-prompt rejections differ between the sweep "
                   "and the reference leg")
    press = load["pressure"]
    if press["preemptions"] < 1:
        bad.append("pressure leg never preempted: the undersized pool "
                   "is not exercising the preemption path")
    if not press["chains_bit_identical"]:
        bad.append("pressure-leg chains diverge after preemption/resume")
    if max_p99_ms is not None and load["p99_ms"] > max_p99_ms:
        bad.append(f"p99 ms/token {load['p99_ms']} > {max_p99_ms}")
    if min_tokens_per_s is not None \
            and load["tokens_per_s"] < min_tokens_per_s:
        bad.append(f"tokens/s {load['tokens_per_s']} < {min_tokens_per_s}")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller batch + fewer requests (CI tier-1)")
    ap.add_argument("--json", default=BENCH_JSON,
                    help=f"output path (default {BENCH_JSON})")
    ap.add_argument("--min-tokens", type=int, default=None, metavar="N",
                    help="fail (exit 1) if fewer than N tokens are served "
                    "(continuous-batching integrity gate)")
    ap.add_argument("--requests", type=int, default=None, metavar="N",
                    help="load-sweep request count "
                    "(default: 120 quick / 500 full)")
    ap.add_argument("--seed", type=int, default=0,
                    help="load-sweep arrival/prompt seed (default 0)")
    ap.add_argument("--max-p99-ms-per-token", type=float, default=None,
                    metavar="MS", help="fail if the sweep's p99 decode "
                    "ms-per-token exceeds MS")
    ap.add_argument("--min-tokens-per-s", type=float, default=None,
                    metavar="TPS", help="fail if sweep throughput drops "
                    "below TPS generated tokens/sec")
    args = ap.parse_args(argv)
    # gates run BEFORE the artifact exists (see bench_util)
    payload = run(json_path=None, quick=args.quick,
                  n_requests=args.requests, seed=args.seed)
    bad = []
    if args.min_tokens is not None:
        bad = check_tokens(payload, args.min_tokens)
    bad += check_fabric_identity(payload)
    bad += check_load(payload, args.max_p99_ms_per_token,
                      args.min_tokens_per_s)
    if bench_util.gate_and_write(payload, bad, args.json, "serve",
                                 repro_path="BENCH_serve_repro.json"):
        return 1
    if args.min_tokens is not None:
        print(f"tokens served >= {args.min_tokens}: OK")
    print("fabric leg tokens bit-identical to ref: OK")
    load = payload["load"]
    print(f"load sweep: {load['requests_done']} requests, chains "
          f"bit-identical to sequential reference, "
          f"{load['rejected']} rejected, "
          f"{load['pressure']['preemptions']} pressure preemptions: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
