"""Serving-engine throughput (smoke-scale model on CPU; the derived
column carries the architectural quantity: decode step tokens/s scale)."""

import time

import numpy as np

import jax

from repro import configs
from repro.models.model import LM
from repro.serve.engine import Request, ServeEngine


def run(print_fn=print):
    cfg = configs.get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_slots=4, capacity=64)
    rng = np.random.default_rng(0)
    for rid in range(8):
        eng.add(Request(rid=rid,
                        prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                        max_new=8))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print_fn(f"serve/continuous_batching,{dt*1e6/max(toks,1):.0f},"
             f"us_per_token;requests={len(done)};slots=4;tokens={toks}")
